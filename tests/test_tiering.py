"""Tiered KV cache: host spill/fetch, priority eviction, warm restarts.

Covers the tier-transition state machine of :mod:`repro.serving.tiering`
(HBM ⇄ host ⇄ disk), the priority-then-LRU fix in the base
:class:`~repro.serving.paged.PrefixCache`, the engine-level bitwise-
identity guarantee, and the warm-restart tolerance for stale stores.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged import BlockAllocator, PrefixCache, prefix_keys
from repro.serving.tiering import HostPool, TieredPrefixCache

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg=CFG):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_engine(host_cache_blocks=None, num_blocks=14, max_batch=2,
                kv_store=None, block_size=16, **kw):
    return ServingEngine(get_model(CFG), init_params(),
                         max_batch=max_batch, max_seq=128, chunk=16,
                         block_size=block_size, num_blocks=num_blocks,
                         host_cache_blocks=host_cache_blocks,
                         kv_store=kv_store, **kw)


# ---------------------------------------------------------------------- #
# fake device I/O: a host-side "pool" of one leaf, block axis 1
# ---------------------------------------------------------------------- #

def fake_pool(num_blocks, width=4):
    dev = {"k": np.zeros((1, num_blocks, width), np.float32)}

    def extract(bids):
        return {"k": dev["k"][:, np.asarray(bids)].copy()}

    def insert(bids, data):
        dev["k"][:, np.asarray(bids)] = data["k"]

    return dev, extract, insert


def make_tiered(num_blocks=8, host_cap=8):
    a = BlockAllocator(num_blocks, 4)
    pc = TieredPrefixCache(a, HostPool(host_cap))
    dev, extract, insert = fake_pool(num_blocks)
    pc.bind_device_io(extract, insert)
    return a, pc, dev


KEYS = prefix_keys(list(range(64)), 4)


def register_chain(a, pc, dev, n, start=0, priority=0):
    """Register n chain blocks with distinct device contents; the owner
    decrefs so each entry is map-only (refcount 1), like a completed
    request's registered prompt blocks."""
    bids = a.alloc(n)
    for j, bid in enumerate(bids):
        dev["k"][:, bid] = float(start + j + 1)
        pc.register(KEYS[start + j], bid, priority=priority)
        a.decref(bid)
    return bids


# ---------------------------------------------------------------------- #
# satellite 1: priority-then-LRU eviction in the base PrefixCache
# ---------------------------------------------------------------------- #

def test_base_evict_priority_then_lru():
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    bids = a.alloc(4)
    # LRU order: k0, k1, k2, k3 — but k1 registered at priority 2
    for j, pri in enumerate([0, 2, 0, 1]):
        pc.register(KEYS[j], bids[j], priority=pri)
        a.decref(bids[j])
    pc.evict(2)
    # priority asc, LRU within class: k0 (pri 0) then k2 (pri 0)
    assert a.refcount(bids[0]) == 0 and a.refcount(bids[2]) == 0
    assert a.refcount(bids[1]) == 1 and a.refcount(bids[3]) == 1
    pc.evict(1)   # next lowest class: k3 (pri 1), NOT k1 (pri 2)
    assert a.refcount(bids[3]) == 0 and a.refcount(bids[1]) == 1


def test_base_evict_all_default_priority_is_plain_lru():
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    bids = a.alloc(4)
    for j in range(4):
        pc.register(KEYS[j], bids[j])
        a.decref(bids[j])
    pc.commit(KEYS[:1], 1)   # touch k0: now LRU order k1, k2, k3, k0
    pc.evict(2)
    assert a.refcount(bids[1]) == 0 and a.refcount(bids[2]) == 0
    assert a.refcount(bids[0]) == 1 and a.refcount(bids[3]) == 1


def test_commit_bumps_priority_protects_entry():
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    bids = a.alloc(2)
    for j in range(2):
        pc.register(KEYS[j], bids[j])
        a.decref(bids[j])
    # a priority-3 request hits the k0 chain: k0's class rises
    pc.commit(KEYS[:1], 1, priority=3)
    pc.evict(1)
    assert a.refcount(bids[0]) == 1, "hot high-priority entry evicted"
    assert a.refcount(bids[1]) == 0


def test_evict_skips_in_use_entries():
    a = BlockAllocator(8, 4)
    pc = PrefixCache(a)
    bids = a.alloc(2)
    for j in range(2):
        pc.register(KEYS[j], bids[j])
        a.decref(bids[j])
    pc.acquire([bids[0]])          # an active request holds k0's block
    assert pc.evictable() == 1
    assert pc.evict(2) == 1        # only k1 can go
    assert a.refcount(bids[0]) == 2
    pc.release([bids[0]])


# ---------------------------------------------------------------------- #
# HostPool
# ---------------------------------------------------------------------- #

def test_host_pool_capacity_and_lru_eviction():
    hp = HostPool(2)
    d = {"k": np.ones((1, 4), np.float32)}
    assert hp.put(b"a", d) and hp.put(b"b", d)
    assert hp.put(b"c", d)             # evicts the LRU entry: a
    assert b"a" not in hp and b"b" in hp and b"c" in hp
    assert hp.used_blocks == 2 and hp.evicted == 1


def test_host_pool_priority_protects_entries():
    hp = HostPool(2)
    d = {"k": np.ones((1, 4), np.float32)}
    hp.put(b"hot1", d, priority=2)
    hp.put(b"hot2", d, priority=2)
    assert not hp.put(b"cold", d, priority=0)   # can't displace hotter
    assert hp.rejected == 1 and b"cold" not in hp
    assert hp.put(b"hotter", d, priority=3)     # can displace cooler
    assert hp.used_blocks == 2 and b"hotter" in hp


def test_host_pool_zero_capacity_rejects():
    hp = HostPool(0)
    assert not hp.put(b"a", {"k": np.ones(2, np.float32)})
    assert hp.rejected == 1


# ---------------------------------------------------------------------- #
# TieredPrefixCache: spill / fetch / no dual residency
# ---------------------------------------------------------------------- #

def test_evict_spills_to_host_and_fetch_restores_bit_exact():
    a, pc, dev = make_tiered(num_blocks=8, host_cap=8)
    register_chain(a, pc, dev, 3)
    orig = {j: dev["k"][:, pc.peek(KEYS[: j + 1])[j]].copy()
            for j in range(3)}
    assert pc.evict(3) == 3
    assert pc.spilled_blocks == 3 and len(pc.host) == 3
    assert a.free_blocks == 7 and len(pc) == 0
    # scribble over the freed device blocks: fetch must restore from host
    dev["k"][:] = -1.0
    hits = pc.fetch_into_hbm(KEYS[:3], [], max_hits=3)
    assert len(hits) == 3 and pc.fetched_blocks == 3
    assert len(pc.host) == 0, "fetched entries still resident in host tier"
    for j, bid in enumerate(hits):
        np.testing.assert_array_equal(dev["k"][:, bid], orig[j])
        assert a.refcount(bid) == 1          # the map's own reference
    assert pc.peek(KEYS[:3]) == hits         # back to ordinary HBM hits


def test_fetch_is_free_block_funded_and_capped():
    a, pc, dev = make_tiered(num_blocks=8, host_cap=8)
    register_chain(a, pc, dev, 4)
    pc.evict(4)                      # all 4 spilled, 7 free
    hold = a.alloc(5)                # squeeze the pool: 2 free
    hits = pc.fetch_into_hbm(KEYS[:4], [], max_hits=4)
    assert len(hits) == 2, "fetch must not exceed free blocks"
    assert len(pc.host) == 2
    # max_hits cap: even with room, never fetch past it
    for b in hold:
        a.decref(b)
    hits = pc.peek(KEYS[:4])
    hits = pc.fetch_into_hbm(KEYS[:4], hits, max_hits=3)
    assert len(hits) == 3 and len(pc.host) == 1


def test_no_key_resident_in_two_tiers_ever():
    a, pc, dev = make_tiered(num_blocks=8, host_cap=8)
    register_chain(a, pc, dev, 3)
    pc.evict(2)
    for k in KEYS[:3]:
        assert not (pc._map.get(k) is not None and k in pc.host)
    pc.fetch_into_hbm(KEYS[:3], pc.peek(KEYS[:3]), max_hits=3)
    for k in KEYS[:3]:
        assert not (pc._map.get(k) is not None and k in pc.host)


def test_spill_honors_host_priority_drops_when_refused():
    a, pc, dev = make_tiered(num_blocks=12, host_cap=2)
    register_chain(a, pc, dev, 2, start=0, priority=5)   # hot chain
    register_chain(a, pc, dev, 2, start=2, priority=0)   # cold chain
    pc.evict(2)          # cold class evicts first: both cold blocks spill
    assert pc.spilled_blocks == 2 and len(pc.host) == 2
    pc.evict(2)          # hot blocks displace the colder host entries
    assert pc.spilled_blocks == 4 and pc.host.evicted == 2
    assert all(pc.host.get(k).priority == 5 for k in pc.host.keys())


def test_unbound_tier_degrades_to_drop():
    a = BlockAllocator(8, 4)
    pc = TieredPrefixCache(a, HostPool(8))   # no bind_device_io
    bids = a.alloc(2)
    for j, bid in enumerate(bids):
        pc.register(KEYS[j], bid)
        a.decref(bid)
    assert pc.evict(2) == 2
    assert pc.dropped_blocks == 2 and len(pc.host) == 0
    assert a.free_blocks == 7


def test_peek_depth_counts_host_continuation():
    a, pc, dev = make_tiered(num_blocks=8, host_cap=8)
    register_chain(a, pc, dev, 4)
    pc.commit(KEYS[:4], 4)                  # LRU: oldest first anyway
    # spill the TAIL of the chain by protecting the head
    pc.acquire(pc.peek(KEYS[:2]))
    pc.evict(2)                             # spills k2, k3
    pc.release(pc.peek(KEYS[:2]))
    assert len(pc.peek(KEYS[:4])) == 2      # HBM run stops at the spill
    assert pc.peek_depth(KEYS[:4]) == 4     # tier-aware depth sees it all
    single = PrefixCache(a)
    assert single.peek_depth(KEYS[:4]) == 0


# ---------------------------------------------------------------------- #
# engine level: bitwise identity, host hits, zero leaks
# ---------------------------------------------------------------------- #

def _churn(eng, fams, max_new=4):
    """Submit each family's prompt twice, one at a time with drains, so
    registration pressure evicts earlier families before their revisit."""
    outs = {}
    uid = 0
    for wave in range(2):
        for p in fams:
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
            uid += 1
            eng.run_until_drained()
    for r in eng.completed:
        outs[r.uid] = list(r.generated)
    return outs


@pytest.fixture(scope="module")
def churn_families():
    rng = np.random.default_rng(7)
    return [rng.integers(1, CFG.vocab_size, 64).tolist() for _ in range(4)]


def test_tiered_streams_bitwise_identical_and_host_hits(churn_families):
    tiered = make_engine(host_cache_blocks=64)
    base = make_engine(host_cache_blocks=None)
    out_t = _churn(tiered, churn_families)
    out_b = _churn(base, churn_families)
    assert out_t == out_b, "tiering changed a token stream"
    s = tiered.scheduler.stats()
    assert s["tier_spilled_blocks"] > 0, "undersized pool never spilled"
    assert s["tier_fetched_blocks"] > 0, "revisits never hit the host tier"
    m = tiered.metrics_summary()
    assert m["mean_host_hit_tokens"] > 0
    # the untiered run on the same undersized pool got no reuse at all
    assert base.metrics_summary()["mean_prefix_hit_tokens"] == 0.0


def test_tiered_full_drain_zero_leaks(churn_families):
    eng = make_engine(host_cache_blocks=64)
    _churn(eng, churn_families)
    pc = eng.scheduler.prefix
    # drop both tiers: every spilled/registered block must come back
    freed = pc.evict(len(pc))
    assert len(pc) == 0
    pc.host.flush()
    assert len(pc.host) == 0
    assert eng.alloc.free_blocks == eng.num_blocks - 1
    assert eng.alloc.check_conservation()


# ---------------------------------------------------------------------- #
# disk tier: warm restart, stale-store tolerance
# ---------------------------------------------------------------------- #

def test_warm_restart_first_wave_hits(tmp_path, churn_families):
    store = str(tmp_path / "kv")
    p = churn_families[0]
    e1 = make_engine(host_cache_blocks=32, kv_store=store)
    e1.submit(Request(uid=0, prompt=p, max_new_tokens=4))
    e1.run_until_drained()
    assert e1.save_kv_store() > 0
    e2 = make_engine(host_cache_blocks=32, kv_store=store)
    assert len(e2.scheduler.prefix.host) > 0, "store not preloaded"
    e2.submit(Request(uid=0, prompt=p, max_new_tokens=4))
    e2.run_until_drained()
    r = e2.completed[0]
    assert r.metrics.prefix_hit_tokens > 0, "warm restart served cold"
    assert r.metrics.host_hit_tokens > 0
    assert r.generated == e1.completed[0].generated


def test_kv_store_defaults_host_tier_on(tmp_path):
    eng = make_engine(kv_store=str(tmp_path / "kv"))
    assert hasattr(eng.scheduler.prefix, "host")
    assert eng.scheduler.prefix.host.capacity > 0


def test_corrupt_store_serves_cold(tmp_path, churn_families):
    store = tmp_path / "kv"
    e1 = make_engine(host_cache_blocks=32, kv_store=str(store))
    e1.submit(Request(uid=0, prompt=churn_families[0], max_new_tokens=4))
    e1.run_until_drained()
    e1.save_kv_store()
    npz = store / "prefix_store.npz"
    npz.write_bytes(npz.read_bytes()[:-8] + b"deadbeef")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e2 = make_engine(host_cache_blocks=32, kv_store=str(store))
    assert any("serving cold" in str(x.message) for x in w)
    assert len(e2.scheduler.prefix.host) == 0
    # and it still serves — cold, same stream
    e2.submit(Request(uid=0, prompt=churn_families[0], max_new_tokens=4))
    e2.run_until_drained()
    assert e2.completed[0].generated == e1.completed[0].generated


def test_layout_mismatch_serves_cold(tmp_path, churn_families):
    store = str(tmp_path / "kv")
    e1 = make_engine(host_cache_blocks=32, kv_store=store)
    e1.submit(Request(uid=0, prompt=churn_families[0], max_new_tokens=4))
    e1.run_until_drained()
    e1.save_kv_store()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e2 = make_engine(host_cache_blocks=32, kv_store=store,
                         block_size=8, num_blocks=28)
    assert any("serving cold" in str(x.message) for x in w)
    assert len(e2.scheduler.prefix.host) == 0


def test_kv_dtype_mismatch_serves_cold(tmp_path, churn_families):
    """A store written by a native-dtype engine must refuse to warm an
    int8 engine (and vice versa): the layout fingerprint includes the
    pool dtype AND the quantized pools' scale leaves, so the mismatch
    shows in both the dtype strings and the leaf set. The unquantized
    engines pin kv_dtype="native" so the int8 CI leg's REPRO_KV_DTYPE
    can't quantize both sides and erase the mismatch."""
    store = str(tmp_path / "kv")
    e1 = make_engine(host_cache_blocks=32, kv_store=store,
                     kv_dtype="native")
    e1.submit(Request(uid=0, prompt=churn_families[0], max_new_tokens=4))
    e1.run_until_drained()
    assert e1.save_kv_store() > 0
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e2 = make_engine(host_cache_blocks=32, kv_store=store,
                         kv_dtype="int8")
    assert any("serving cold" in str(x.message)
               and issubclass(x.category, RuntimeWarning) for x in w)
    assert len(e2.scheduler.prefix.host) == 0, "quantized engine warmed "\
        "from an unquantized store"
    # the int8 engine still serves (cold), then persists ITS layout —
    # which must in turn refuse to warm a native-dtype engine
    e2.submit(Request(uid=0, prompt=churn_families[0], max_new_tokens=4))
    e2.run_until_drained()
    assert e2.save_kv_store() > 0
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        e3 = make_engine(host_cache_blocks=32, kv_store=store,
                         kv_dtype="native")
    assert any("serving cold" in str(x.message) for x in w)
    assert len(e3.scheduler.prefix.host) == 0
    # matching dtype: the int8 store warms an int8 engine normally
    e4 = make_engine(host_cache_blocks=32, kv_store=store,
                     kv_dtype="int8")
    assert len(e4.scheduler.prefix.host) > 0, "int8 store failed to warm "\
        "a matching int8 engine"


def test_missing_store_is_silent_first_run(tmp_path):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = make_engine(host_cache_blocks=32,
                          kv_store=str(tmp_path / "never_written"))
    assert not [x for x in w if "serving cold" in str(x.message)]
    assert len(eng.scheduler.prefix.host) == 0


# ---------------------------------------------------------------------- #
# router: tier-aware affinity
# ---------------------------------------------------------------------- #

def test_router_affinity_sees_host_tier(churn_families):
    from repro.serving.router import Router
    e0 = make_engine(host_cache_blocks=64)
    e1 = make_engine(host_cache_blocks=64)
    router = Router([e0, e1], seed=0)
    p = churn_families[0]
    # prime replica 1 with the prefix, then spill it to its host pool
    e1.submit(Request(uid=1000, prompt=p, max_new_tokens=4))
    e1.run_until_drained()
    pc = e1.scheduler.prefix
    pc.evict(len(pc))
    assert len(pc.host) > 0 and len(pc) == 0
    assert pc.peek(prefix_keys(p[:127], 16)) == []
    # the router must still route the revisit onto replica 1
    req = Request(uid=2000, prompt=p, max_new_tokens=4)
    assert router.route(req) == 1
    assert router.affinity_hits == 1
