
"""Paper §2.1/§2.2: Variable/Function graph engine, both execution modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
import repro.core.functions as F
import repro.core.parametric as PF


def test_listing1_affine_forward_backward():
    """Paper Listing 1, line for line."""
    x = nn.Variable((16, 10), need_grad=True)
    y = PF.affine(x, 5)
    x.d = np.random.default_rng(0).random((16, 10))
    y.forward()
    y.backward()
    params = nn.get_parameters()
    assert set(params) == {"affine/W", "affine/b"}
    assert y.shape == (16, 5)
    assert np.asarray(x.g).shape == (16, 10)
    assert params["affine/W"].grad is not None


def test_static_graph_grads_match_jax_grad():
    x = nn.Variable(data=np.random.default_rng(1).random((4, 8)).astype(np.float32),
                    need_grad=True)
    h = F.relu(PF.affine(x, 6, name="l1"))
    loss = F.sum(F.mul(h, h))
    loss.forward()
    loss.backward()
    W = nn.get_parameters()["l1/affine/W"] if "l1/affine/W" in nn.get_parameters() \
        else nn.get_parameters()["l1/W"]
    w, b = W.data, nn.get_parameters()[[k for k in nn.get_parameters() if k.endswith("/b")][0]].data

    def ref(xv, wv, bv):
        hh = jnp.maximum(xv.reshape(4, 8) @ wv + bv, 0)
        return jnp.sum(hh * hh)

    gx, gw = jax.grad(ref, argnums=(0, 1))(jnp.asarray(x.d), w, b)
    np.testing.assert_allclose(np.asarray(x.g), np.asarray(gx), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(W.grad), np.asarray(gw), rtol=1e-5)


def test_dynamic_mode_executes_immediately():
    with nn.auto_forward():
        x = nn.Variable(data=np.ones((2, 3), np.float32), need_grad=True)
        h = F.exp(x)
        assert h.data is not None           # computed at op call
        np.testing.assert_allclose(np.asarray(h.data), np.e, rtol=1e-6)
        F.sum(h).backward()
        np.testing.assert_allclose(np.asarray(x.g), np.e, rtol=1e-6)


def test_static_deferred_until_forward():
    x = nn.Variable(data=np.ones((2, 2), np.float32))
    y = F.exp(x)
    assert y.data is None                   # deferred
    assert y.shape == (2, 2)                # but shape-inferred (nnabla parity)
    y.forward()
    assert y.data is not None


def test_same_code_both_modes_same_result():
    def model(x):
        return F.sum(F.tanh(PF.affine(x, 4, name="m")))

    data = np.random.default_rng(2).random((3, 5)).astype(np.float32)
    x1 = nn.Variable(data=data, need_grad=True)
    y1 = model(x1)
    y1.forward()
    static_val = float(y1.data)

    with nn.auto_forward():
        x2 = nn.Variable(data=data, need_grad=True)
        y2 = model(x2)                       # params reused from registry
    assert abs(float(y2.data) - static_val) < 1e-6


def test_backward_loss_scale_seed():
    x = nn.Variable(data=np.ones((2, 2), np.float32), need_grad=True)
    y = F.sum(F.mul(x, x))
    y.forward()
    y.backward(grad=8.0)                     # paper Listing 6: backward(scale)
    np.testing.assert_allclose(np.asarray(x.g), 8.0 * 2.0 * np.ones((2, 2)))


def test_compiled_graph_matches_eager():
    x = nn.Variable(data=np.random.default_rng(3).random((4, 4)).astype(np.float32),
                    need_grad=True)
    y = F.sum(F.silu(PF.affine(x, 4, name="cg")))
    y.forward()
    eager = float(y.data)
    cg = nn.compile_graph(y)
    cg.forward()
    assert abs(float(y.data) - eager) < 1e-6
    cg.backward(1.0)
    assert x.grad is not None


def test_operator_sugar_and_shapes():
    a = nn.Variable(data=np.full((2, 2), 3.0, np.float32), need_grad=True)
    b = nn.Variable(data=np.full((2, 2), 2.0, np.float32))
    y = (a * b + a - b / a).sum()
    y.forward()
    np.testing.assert_allclose(float(y.data), 4 * (6 + 3 - 2 / 3.0), rtol=1e-6)


def test_multi_output_split_top_k():
    x = nn.Variable(data=np.asarray([[5.0, 1.0, 3.0]], np.float32))
    vals, idx = F.top_k(x, k=2)
    vals.forward()
    np.testing.assert_allclose(np.asarray(vals.data), [[5.0, 3.0]])
