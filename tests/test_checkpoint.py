
"""Checkpoint manager: atomicity, integrity, resume, elastic reshape."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def state_of(seed):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.random((4, 4)), jnp.float32),
                       "b": jnp.asarray(rng.random(4), jnp.float32)},
            "step": jnp.asarray(seed, jnp.int32)}


def test_save_restore_bitwise(tmp_path):
    mgr = CheckpointManager(tmp_path)
    s = state_of(3)
    mgr.save(3, s)
    got = mgr.restore(3, jax.tree.map(np.asarray, s))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_n(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for i in (1, 2, 3, 4):
        mgr.save(i, state_of(i))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_integrity_check_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, state_of(7))
    npz = tmp_path / "step_0000000007" / "state.npz"
    data = bytearray(npz.read_bytes())
    data[100] ^= 0xFF
    npz.write_bytes(bytes(data))
    with pytest.raises(IOError, match="integrity"):
        mgr.restore(7, jax.tree.map(np.asarray, state_of(7)))


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    s = state_of(11)
    mgr.save_async(11, s, extra={"pipe": {"step": 11}})
    mgr.wait()
    step, got = mgr.restore_latest(jax.tree.map(np.asarray, s))
    assert step == 11
    meta = json.loads((tmp_path / "step_0000000011" / "meta.json").read_text())
    assert meta["extra"]["pipe"]["step"] == 11


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs are never counted as checkpoints (atomic publish)."""
    mgr = CheckpointManager(tmp_path)
    (tmp_path / ".tmp-deadbeef").mkdir()
    assert mgr.all_steps() == []


def test_training_resume_bitwise(tmp_path):
    """Kill-and-restart: resumed run replays to identical state."""
    import repro.core as nn
    import repro.core.parametric as PF
    import repro.core.functions as F
    from repro.distributed.train_step import (init_train_state,
                                              make_train_step)
    from repro.precision.loss_scale import static_scaler
    from repro.solvers import Adam
    from repro.data.pipeline import SyntheticLMPipeline
    from repro.configs.base import ModelConfig, ShapeConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                      head_dim=16, remat="none")
    shape = ShapeConfig("t", 16, 4, "train")
    pipe = SyntheticLMPipeline(cfg, shape, seed=5)
    from repro.models.registry import get_model
    api = get_model(cfg)

    def loss_fn(p, b):
        return nn.apply(lambda **kw: api.loss_fn(**kw), p, **b)

    params = nn.init(lambda **kw: api.loss_fn(**kw), jax.random.key(0),
                     **{k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()})
    solver = Adam(alpha=1e-3)
    scaler = static_scaler(1.0)
    step = jax.jit(make_train_step(loss_fn, solver, scaler))

    # run 6 steps straight
    s_ref = init_train_state(params, solver, scaler)
    for i in range(6):
        s_ref, _ = step(s_ref, {k: jnp.asarray(v)
                                for k, v in pipe.batch_at(i).items()})

    # run 3, checkpoint, "crash", restore, run 3 more
    mgr = CheckpointManager(tmp_path)
    s = init_train_state(params, solver, scaler)
    for i in range(3):
        s, _ = step(s, {k: jnp.asarray(v)
                        for k, v in pipe.batch_at(i).items()})
    mgr.save(3, s)
    restored = mgr.restore(3, jax.tree.map(np.asarray, s))
    s2 = jax.tree.map(jnp.asarray, restored)
    for i in range(3, 6):
        s2, _ = step(s2, {k: jnp.asarray(v)
                          for k, v in pipe.batch_at(i).items()})
    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
