"""Monitors (nnabla.monitor parity) and LR schedules."""

import jax.numpy as jnp
import numpy as np

from repro.monitor import Monitor, MonitorCSV, MonitorSeries
from repro.solvers.schedules import cosine, inverse_sqrt, step_decay


def test_series_interval_average(tmp_path, capsys):
    mon = Monitor(tmp_path)
    s = MonitorSeries("loss", mon, interval=5)
    for i in range(10):
        s.add(i, float(i))
    s.close()
    lines = (tmp_path / "loss.txt").read_text().strip().splitlines()
    assert len(lines) == 2
    idx, mean = lines[0].split()
    assert idx == "4" and abs(float(mean) - 2.0) < 1e-9   # mean(0..4)


def test_csv_roundtrip_and_append(tmp_path):
    p = tmp_path / "m.csv"
    m = MonitorCSV(p, ["loss", "lr"])
    m.add(0, loss=1.5, lr=0.1)
    m.add(1, loss=1.2, lr=0.1)
    m.close()
    m2 = MonitorCSV(p, ["loss", "lr"])  # append after "restart"
    m2.add(2, loss=1.0, lr=0.05)
    m2.close()
    rows = MonitorCSV.read(p)
    assert len(rows) == 3 and rows[2]["loss"] == 1.0


def test_cosine_schedule_shape():
    f = cosine(1.0, total_steps=100, warmup_steps=10, final_fraction=0.1)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert abs(float(f(100)) - 0.1) < 1e-6
    # monotone decay after warmup
    vals = [float(f(i)) for i in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_inverse_sqrt_and_step_decay():
    g = inverse_sqrt(1.0, warmup_steps=100)
    assert abs(float(g(100)) - 1.0) < 1e-6
    assert abs(float(g(400)) - 0.5) < 1e-6
    h = step_decay(1.0, gamma=0.1, every=30)
    assert abs(float(h(29)) - 1.0) < 1e-9
    assert abs(float(h(30)) - 0.1) < 1e-7
    assert abs(float(h(60)) - 0.01) < 1e-7


def test_schedule_jit_safe():
    import jax
    f = cosine(3e-4, 1000, 50)
    out = jax.jit(f)(jnp.asarray(500))
    assert np.isfinite(float(out))
