
"""Serving engine: continuous batching semantics."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")


def make_engine(max_batch=3, max_seq=64):
    api = get_model(CFG)
    params = nn.init(lambda t: T.forward(CFG, t), jax.random.key(0),
                     jnp.zeros((1, 8), jnp.int32))
    return ServingEngine(api, params, max_batch=max_batch, max_seq=max_seq)


def test_all_requests_complete():
    eng = make_engine()
    for i in range(7):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(r.generated) == 5 for r in done)


def test_batched_equals_solo():
    eng = make_engine(max_batch=4)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=[5 + i, 6, 7], max_new_tokens=6))
    done = {r.uid: r.generated for r in eng.run_until_drained()}
    for i in range(4):
        solo_eng = make_engine(max_batch=1)
        solo_eng.submit(Request(uid=0, prompt=[5 + i, 6, 7],
                                max_new_tokens=6))
        solo = solo_eng.run_until_drained()[0].generated
        assert solo == done[i], f"request {i}: batching changed the output"


def test_slot_reuse_after_completion():
    eng = make_engine(max_batch=2)
    eng.submit(Request(uid=0, prompt=[1], max_new_tokens=2))
    eng.submit(Request(uid=1, prompt=[2], max_new_tokens=8))
    eng.submit(Request(uid=2, prompt=[3], max_new_tokens=2))  # queued
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1, 2}


def test_greedy_determinism():
    eng1 = make_engine()
    eng1.submit(Request(uid=0, prompt=[9, 8], max_new_tokens=4))
    out1 = eng1.run_until_drained()[0].generated
    eng2 = make_engine()
    eng2.submit(Request(uid=0, prompt=[9, 8], max_new_tokens=4))
    assert eng2.run_until_drained()[0].generated == out1
