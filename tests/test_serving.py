
"""Serving engine: continuous batching, chunked prefill, sampling."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as nn
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.registry import get_model
from repro.serving import sampling
from repro.serving.engine import Request, RequestMetrics, ServingEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  head_dim=16, remat="none")

# one tiny config per LM family in models/registry.py (audio needs frames
# and has no prefill entry). moe: group size covers any ragged B*C so the
# dispatch group is always the whole token set, and capacity_factor >= E/k
# guarantees no token dropping — routing then commutes with chunking.
LM_CFGS = [
    CFG,
    ModelConfig(name="moe", family="moe", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=97, head_dim=16,
                n_experts=4, top_k=2, capacity_factor=4.0, moe_group_size=64,
                remat="none"),
    ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=97, head_dim=16,
                ssm_state=16, ssm_head_dim=32, ssm_chunk=4, remat="none"),
    ModelConfig(name="hyb", family="hybrid", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                head_dim=16, ssm_state=16, ssm_head_dim=32, ssm_chunk=4,
                attn_every=2, remat="none"),
]

_PARAMS_CACHE: dict[str, dict] = {}


def init_params(cfg):
    if cfg.name not in _PARAMS_CACHE:
        api = get_model(cfg)
        _PARAMS_CACHE[cfg.name] = nn.init(
            lambda t: api.forward(t), jax.random.key(0),
            jnp.zeros((1, 8), jnp.int32))
    return _PARAMS_CACHE[cfg.name]


def make_engine(max_batch=3, max_seq=64, chunk=8, cfg=CFG):
    return ServingEngine(get_model(cfg), init_params(cfg),
                         max_batch=max_batch, max_seq=max_seq, chunk=chunk)


# ---------------------------------------------------------------------- #
# continuous batching semantics (pre-existing behavior)
# ---------------------------------------------------------------------- #

def test_all_requests_complete():
    eng = make_engine()
    for i in range(7):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(r.generated) == 5 for r in done)


def test_batched_equals_solo():
    eng = make_engine(max_batch=4)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=[5 + i, 6, 7], max_new_tokens=6))
    done = {r.uid: r.generated for r in eng.run_until_drained()}
    for i in range(4):
        solo_eng = make_engine(max_batch=1)
        solo_eng.submit(Request(uid=0, prompt=[5 + i, 6, 7],
                                max_new_tokens=6))
        solo = solo_eng.run_until_drained()[0].generated
        assert solo == done[i], f"request {i}: batching changed the output"


def test_slot_reuse_after_completion():
    eng = make_engine(max_batch=2)
    eng.submit(Request(uid=0, prompt=[1], max_new_tokens=2))
    eng.submit(Request(uid=1, prompt=[2], max_new_tokens=8))
    eng.submit(Request(uid=2, prompt=[3], max_new_tokens=2))  # queued
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1, 2}


def test_greedy_determinism():
    eng1 = make_engine()
    eng1.submit(Request(uid=0, prompt=[9, 8], max_new_tokens=4))
    out1 = eng1.run_until_drained()[0].generated
    eng2 = make_engine()
    eng2.submit(Request(uid=0, prompt=[9, 8], max_new_tokens=4))
    assert eng2.run_until_drained()[0].generated == out1


# ---------------------------------------------------------------------- #
# chunked prefill: logits equivalence across every LM arch
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("chunk", [4, 5], ids=["divides", "ragged"])
@pytest.mark.parametrize("cfg", LM_CFGS, ids=[c.family for c in LM_CFGS])
def test_prefill_matches_decode_and_forward(cfg, chunk):
    """Chunked prefill == token-by-token decode == forward(last_only=True).

    plen=12: chunk 4 divides it, chunk 5 leaves a ragged 2-token tail."""
    api = get_model(cfg)
    params = init_params(cfg)
    B, plen, max_seq = 2, 12, 40
    rng = np.random.default_rng(3)
    toks = rng.integers(1, cfg.vocab_size, (B, plen)).astype(np.int32)

    # token-by-token teacher-forced decode
    state = api.decode_state_init(B, max_seq, jnp.float32)
    dec = jax.jit(lambda p, t, s, pos: nn.apply(
        lambda tt, ss, pp: api.decode_step(tt, ss, pp), p, t, s, pos))
    for i in range(plen):
        logits_dec, state = dec(params, jnp.asarray(toks[:, i:i + 1]), state,
                                jnp.full((B,), i, jnp.int32))

    # chunked prefill (padded final chunk when chunk doesn't divide plen)
    state2 = api.decode_state_init(B, max_seq, jnp.float32)
    pf = jax.jit(lambda p, t, s, pos, ln: nn.apply(
        lambda tt, ss, pp, ll: api.prefill(tt, ss, pp, ll),
        p, t, s, pos, ln))
    off = 0
    while off < plen:
        k = min(chunk, plen - off)
        buf = np.zeros((B, chunk), np.int32)
        buf[:, :k] = toks[:, off:off + k]
        logits_pf, state2 = pf(params, jnp.asarray(buf), state2,
                               jnp.full((B,), off, jnp.int32),
                               jnp.full((B,), k, jnp.int32))
        off += k

    logits_fwd, _ = nn.apply(lambda t: api.forward(t, last_only=True),
                             params, jnp.asarray(toks))
    a = np.asarray(logits_dec[:, -1], np.float32)
    b = np.asarray(logits_pf[:, -1], np.float32)
    c = np.asarray(logits_fwd[:, -1], np.float32)
    np.testing.assert_allclose(b, a, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(b, c, atol=2e-4, rtol=2e-4)


def test_moe_prefill_pads_cannot_steal_capacity():
    """With a *tight* capacity factor, a padded chunk must give the same
    logits regardless of what garbage sits in the pad columns — pads are
    masked out of routing, so they can't consume expert capacity."""
    cfg = ModelConfig(name="moe-tight", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      head_dim=16, n_experts=4, top_k=2, capacity_factor=1.0,
                      moe_group_size=64, remat="none")
    api = get_model(cfg)
    params = init_params(cfg)
    B, plen, C = 2, 5, 8
    toks = np.arange(1, 1 + B * plen).reshape(B, plen).astype(np.int32)
    outs = []
    for pad_value in (0, 61):
        buf = np.full((B, C), pad_value, np.int32)
        buf[:, :plen] = toks
        state = api.decode_state_init(B, 32, jnp.float32)
        logits, _ = nn.apply(
            lambda t, s, p, l: api.prefill(t, s, p, l), params,
            jnp.asarray(buf), state, jnp.zeros(B, jnp.int32),
            jnp.full(B, plen, jnp.int32))
        outs.append(np.asarray(logits, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6, rtol=1e-6)


def test_engine_chunked_equals_tokenwise():
    """The engine generates the same greedy tokens whether prompts are
    absorbed in one fused chunk or token by token (prompt len 7 doesn't
    divide chunk 8 — exercises the padded path end-to-end)."""
    outs = []
    for chunk in (8, 1):
        eng = make_engine(max_batch=2, chunk=chunk)
        for i in range(2):
            eng.submit(Request(uid=i, prompt=[3 + i, 1, 4, 1, 5, 9, 2],
                               max_new_tokens=6))
        outs.append({r.uid: r.generated for r in eng.run_until_drained()})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------- #
# engine edge cases
# ---------------------------------------------------------------------- #

def test_eos_on_first_sampled_token():
    probe = make_engine(max_batch=1)
    probe.submit(Request(uid=0, prompt=[7, 7, 7], max_new_tokens=4))
    first = probe.run_until_drained()[0].generated[0]

    eng = make_engine(max_batch=1)
    eng.submit(Request(uid=0, prompt=[7, 7, 7], max_new_tokens=4,
                       eos_id=first))
    done = eng.run_until_drained()[0]
    assert done.done and done.generated == [first]


def test_slot_refill_fifo_under_deep_queue():
    eng = make_engine(max_batch=2)
    for i in range(9):
        eng.submit(Request(uid=i, prompt=[1 + i], max_new_tokens=3))
    done = eng.run_until_drained()
    assert {r.uid for r in done} == set(range(9))
    # FIFO admission: a request is never admitted before an earlier one
    admits = [r.metrics.admit_t for r in sorted(done, key=lambda r: r.uid)]
    assert all(a <= b for a, b in zip(admits, admits[1:]))
    assert all(r.metrics.queue_wait >= 0 for r in done)


def test_max_seq_truncation():
    max_seq = 16
    eng = make_engine(max_batch=1, max_seq=max_seq, chunk=4)
    eng.submit(Request(uid=0, prompt=list(range(1, 40)), max_new_tokens=8))
    done = eng.run_until_drained()[0]
    # prompt truncated to max_seq-1 tokens; the cache fills right after the
    # first sampled token, so exactly one token comes out
    assert done.done and len(done.generated) == 1


def test_slot_reuse_resets_ssm_state():
    """A reused slot must not leak the previous request's SSM state."""
    cfg = LM_CFGS[2]
    ref_eng = make_engine(max_batch=1, cfg=cfg)
    ref_eng.submit(Request(uid=0, prompt=[5, 6, 7], max_new_tokens=4))
    want = ref_eng.run_until_drained()[0].generated

    eng = make_engine(max_batch=1, cfg=cfg)
    eng.submit(Request(uid=0, prompt=[9, 8, 7, 6, 5], max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=[5, 6, 7], max_new_tokens=4))
    got = {r.uid: r.generated for r in eng.run_until_drained()}
    assert got[1] == want


def test_metrics_nan_safe_before_events():
    """ttft read before the first token lands must be NaN, not a garbage
    negative epoch delta; same for queue_wait before admission."""
    m = RequestMetrics()
    assert math.isnan(m.ttft) and math.isnan(m.queue_wait)
    m.submit_t = 100.0                 # submitted, nothing else yet
    assert math.isnan(m.ttft), "ttft leaked a -submit_t epoch delta"
    assert math.isnan(m.queue_wait)
    m.admit_t = 100.5
    assert m.queue_wait == pytest.approx(0.5)
    m.first_token_t = 101.0
    assert m.ttft == pytest.approx(1.0)


def test_metrics_decode_rate_single_token_is_nan():
    """A single-token generation has no decode interval: the rate is NaN
    (undefined), not a fake 0.0 that drags aggregate means down."""
    m = RequestMetrics(submit_t=1.0, first_token_t=2.0, done_t=2.0)
    assert math.isnan(m.decode_tok_per_s(1))
    assert math.isnan(m.decode_tok_per_s(0))
    m.done_t = 4.0
    assert m.decode_tok_per_s(5) == pytest.approx(2.0)
    # zero/negative span (clock resolution): still NaN, never inf
    m.done_t = m.first_token_t
    assert math.isnan(m.decode_tok_per_s(3))


def test_metrics_summary_excludes_nan_entries():
    """End-to-end: a single-token request must not zero out (old bug) or
    NaN-poison the aggregate decode rate."""
    eng = make_engine(max_batch=2, chunk=4)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=1))
    eng.submit(Request(uid=1, prompt=[4, 5, 6], max_new_tokens=8))
    done = eng.run_until_drained()
    assert len(done) == 2
    single = next(r for r in done if r.uid == 0)
    assert math.isnan(single.metrics.decode_tok_per_s(
        len(single.generated)))
    summary = eng.metrics_summary()
    assert not math.isnan(summary["mean_decode_tok_per_s"])
    assert summary["mean_decode_tok_per_s"] > 0
    assert not math.isnan(summary["mean_ttft_s"])


def test_metrics_recorded():
    eng = make_engine(max_batch=2, chunk=4)
    eng.submit(Request(uid=0, prompt=list(range(1, 10)), max_new_tokens=5))
    done = eng.run_until_drained()[0]
    m = done.metrics
    assert m.ttft > 0 and m.queue_wait >= 0
    assert m.prefill_steps == 3           # ceil(9 / 4) chunks
    assert m.decode_steps == 4            # 5 tokens, first from prefill
    summary = eng.metrics_summary()
    assert summary["requests"] == 1 and summary["mean_ttft_s"] > 0


def test_run_until_drained_raises_on_step_exhaustion():
    """Hitting max_steps with requests still queued/active must raise,
    not silently return a partial drain — a wedged pool would otherwise
    masquerade as a clean one."""
    eng = make_engine(max_batch=1, chunk=4)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=8))
    eng.submit(Request(uid=1, prompt=[4, 5, 6], max_new_tokens=8))
    with pytest.raises(RuntimeError, match="queued requests undrained"):
        eng.run_until_drained(max_steps=2)
    # the workload is fine, just longer than 2 steps: a real drain works
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1}
    # and an already-drained engine returns immediately, even max_steps=0
    assert eng.run_until_drained(max_steps=0) is done


# ---------------------------------------------------------------------- #
# sampling
# ---------------------------------------------------------------------- #

def _sample_args(B, V=97):
    return dict(temperature=jnp.ones((B,), jnp.float32),
                top_k=jnp.zeros((B,), jnp.int32),
                top_p=jnp.ones((B,), jnp.float32),
                seed=jnp.arange(B, dtype=jnp.int32),
                count=jnp.zeros((B,), jnp.int32))


def test_sampling_greedy_and_topk1():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 97)), jnp.float32)
    args = _sample_args(3)
    greedy = sampling.sample(logits, jnp.zeros((3,), jnp.float32),
                             args["top_k"], args["top_p"], args["seed"],
                             args["count"])
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), -1))
    # top_k=1 at any temperature collapses to argmax
    k1 = sampling.sample(logits, args["temperature"],
                         jnp.ones((3,), jnp.int32), args["top_p"],
                         args["seed"], args["count"])
    np.testing.assert_array_equal(np.asarray(k1),
                                  np.argmax(np.asarray(logits), -1))


def test_sampling_top_p_collapses_to_argmax():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(3, 97)) * 5, jnp.float32)
    args = _sample_args(3)
    out = sampling.sample(logits, args["temperature"], args["top_k"],
                          jnp.full((3,), 1e-4, jnp.float32),
                          args["seed"], args["count"])
    np.testing.assert_array_equal(np.asarray(out),
                                  np.argmax(np.asarray(logits), -1))


def test_sampling_seed_determinism():
    """Same (seed, count) -> same token; the stream varies with count and
    the engine reproduces it run-to-run."""
    logits = jnp.zeros((1, 97), jnp.float32)  # uniform: pure PRNG behavior
    t = jnp.ones((1,), jnp.float32)
    k = jnp.zeros((1,), jnp.int32)
    p = jnp.ones((1,), jnp.float32)
    s = jnp.asarray([42], jnp.int32)
    draws = [int(sampling.sample(logits, t, k, p, s,
                                 jnp.asarray([c], jnp.int32))[0])
             for c in range(12)]
    again = [int(sampling.sample(logits, t, k, p, s,
                                 jnp.asarray([c], jnp.int32))[0])
             for c in range(12)]
    assert draws == again
    assert len(set(draws)) > 1  # it actually samples

    def run_engine(seed):
        eng = make_engine(max_batch=1)
        eng.submit(Request(uid=0, prompt=[2, 3], max_new_tokens=6,
                           temperature=1.0, seed=seed))
        return eng.run_until_drained()[0].generated

    assert run_engine(7) == run_engine(7)
    assert run_engine(7) != run_engine(8)


def test_mixed_greedy_sampled_batch_bitwise():
    """A temperature-0 row inside a do_sample batch must emit exactly
    the all-greedy stream: the batched sampler's temperature guard (and
    the per-row top-k/top-p masks) cannot bleed across rows."""
    def submit_all(eng, temps):
        for i, t in enumerate(temps):
            eng.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                               max_new_tokens=8, temperature=t,
                               top_k=20, top_p=0.9, seed=11 + i))
        return {r.uid: list(r.generated)
                for r in eng.run_until_drained()}

    pure = submit_all(make_engine(max_batch=4), [0.0, 0.0, 0.0, 0.0])
    mixed = submit_all(make_engine(max_batch=4), [0.0, 0.9, 0.0, 0.9])
    assert mixed[0] == pure[0] and mixed[2] == pure[2]
    # and the sampled rows really sampled (same engine, same seeds)
    again = submit_all(make_engine(max_batch=4), [0.0, 0.9, 0.0, 0.9])
    assert again == mixed


# ---------------------------------------------------------------------- #
# request-level metrics surfaced through metrics_summary / the serve CLI
# ---------------------------------------------------------------------- #

def test_truncated_request_counted_in_metrics_summary():
    eng = make_engine(max_batch=2, max_seq=16, chunk=8)
    with pytest.warns(RuntimeWarning, match="truncated"):
        eng.submit(Request(uid=0, prompt=[1 + j % 90 for j in range(40)],
                           max_new_tokens=3))
    eng.submit(Request(uid=1, prompt=[5, 6, 7], max_new_tokens=3))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[0].truncated and not done[1].truncated
    # the submitted prompt is preserved; only the engine's working copy
    # was clipped to max_seq - 1
    assert len(done[0].prompt) == 40
    # clipping to max_seq - 1 leaves exactly one position to generate
    assert len(done[0].generated) == 1
    assert len(done[1].generated) == 3
    m = eng.metrics_summary()
    assert m["truncated_requests"] == 1.0


def test_queue_wait_stints_surface_in_metrics_summary():
    eng = make_engine(max_batch=1)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1 + i, 2], max_new_tokens=4))
    done = eng.run_until_drained()
    # the scheduler's per-stint accumulator ran for every admitted request
    assert all(not math.isnan(r.metrics.queued_s) for r in done)
    waits = {r.uid: r.metrics.queue_wait for r in done}
    assert all(w >= 0.0 for w in waits.values())
    # max_batch=1 serializes: each later request queues behind the
    # previous one's full service time
    assert waits[2] >= waits[1] >= waits[0]
    m = eng.metrics_summary()
    assert m["mean_queue_wait_s"] == pytest.approx(
        sum(waits.values()) / 3, rel=1e-6, abs=1e-9)


def test_serve_cli_metrics_line_reports_truncation(capsys):
    """The batch-mode CLI must surface truncated prompts on its metrics
    line — a clipped response that prints as healthy is a silent wrong
    answer."""
    import warnings as _warnings

    from repro.launch import serve

    with _warnings.catch_warnings():
        _warnings.simplefilter("ignore", RuntimeWarning)
        rc = serve.main(["--smoke", "--requests", "2", "--max-new", "3",
                         "--prompt-len", "40", "--max-seq", "32",
                         "--max-batch", "2"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "mean TTFT" in out and "mean queue wait" in out
    assert "2 truncated prompts" in out
